//! CliqueService snapshot-isolation proof: queries issued from pool
//! threads *while* batches (insertions and removals) land must each be
//! exactly correct for *some* published epoch — never a blend of two —
//! and the incrementally maintained inverted index must equal a
//! from-scratch rebuild after every replay.

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use parmce::coordinator::pool::ThreadPool;
use parmce::dynamic::stream::EdgeStream;
use parmce::graph::adj::DynGraph;
use parmce::graph::csr::CsrGraph;
use parmce::graph::generators;
use parmce::graph::{Edge, Vertex};
use parmce::mce::oracle;
use parmce::service::{CliqueService, CliqueSnapshot};
use parmce::session::DynAlgo;
use parmce::util::rng::Rng;

type CliqueSet = BTreeSet<Vec<Vertex>>;

#[derive(Clone, Copy)]
enum Op<'a> {
    Insert(&'a [Edge]),
    Remove(&'a [Edge]),
}

/// Expected C(G) per epoch: epoch 0 is the empty graph on `n` vertices,
/// epoch i the state after ops[..i] — computed independently of the
/// service via the Bron–Kerbosch oracle on a mirror graph.
fn expected_per_epoch(n: usize, ops: &[Op<'_>]) -> Vec<CliqueSet> {
    let mut mirror = DynGraph::new(n);
    let mut expected = Vec::with_capacity(ops.len() + 1);
    expected.push(oracle_set(&mirror.to_csr()));
    for op in ops {
        match op {
            Op::Insert(edges) => {
                mirror.insert_batch(edges);
            }
            Op::Remove(edges) => {
                for &(u, v) in *edges {
                    mirror.remove_edge(u, v);
                }
            }
        }
        expected.push(oracle_set(&mirror.to_csr()));
    }
    expected
}

fn oracle_set(g: &CsrGraph) -> CliqueSet {
    oracle::maximal_cliques(g).into_iter().collect()
}

/// A multi-query observation taken from ONE snapshot. If the snapshot
/// blended two batches, at least one field disagrees with every single
/// per-epoch expectation.
struct Observation {
    epoch: u64,
    count: usize,
    probe_v: Vertex,
    containing: Vec<Vec<Vertex>>,
    probe_pair: (Vertex, Vertex),
    containing_pair: Vec<Vec<Vertex>>,
    top: Vec<Vec<Vertex>>,
    sampled_maximal: Option<(Vec<Vertex>, bool)>,
}

fn observe(snap: &CliqueSnapshot, rng: &mut Rng, n: usize) -> Observation {
    let probe_v = rng.gen_usize(n) as Vertex;
    let u = rng.gen_usize(n) as Vertex;
    let w = rng.gen_usize(n) as Vertex;
    let sampled = snap
        .ids_containing(probe_v)
        .first()
        .map(|&id| {
            let c = snap.clique(id).expect("live id").to_vec();
            let ok = snap.is_maximal_clique(&c);
            (c, ok)
        });
    Observation {
        epoch: snap.epoch(),
        count: snap.count(),
        probe_v,
        containing: snap.cliques_containing(probe_v).iter().map(|c| c.to_vec()).collect(),
        probe_pair: (u, w),
        containing_pair: snap.cliques_containing_all(&[u, w]).iter().map(|c| c.to_vec()).collect(),
        top: snap.top_k_largest(3).iter().map(|c| c.to_vec()).collect(),
        sampled_maximal: sampled,
    }
}

fn check_observation(obs: &Observation, expected: &[CliqueSet]) -> Result<(), String> {
    let e = obs.epoch as usize;
    let Some(exp) = expected.get(e) else {
        return Err(format!("answer tagged with unknown epoch {e}"));
    };
    if obs.count != exp.len() {
        return Err(format!(
            "epoch {e}: count {} != expected {}",
            obs.count,
            exp.len()
        ));
    }
    let want_containing: BTreeSet<&Vec<Vertex>> = exp
        .iter()
        .filter(|c| c.binary_search(&obs.probe_v).is_ok())
        .collect();
    let got_containing: BTreeSet<&Vec<Vertex>> = obs.containing.iter().collect();
    if got_containing != want_containing {
        return Err(format!(
            "epoch {e}: cliques_containing({}) diverged",
            obs.probe_v
        ));
    }
    let (u, w) = obs.probe_pair;
    let want_pair: BTreeSet<&Vec<Vertex>> = exp
        .iter()
        .filter(|c| c.binary_search(&u).is_ok() && c.binary_search(&w).is_ok())
        .collect();
    let got_pair: BTreeSet<&Vec<Vertex>> = obs.containing_pair.iter().collect();
    if got_pair != want_pair {
        return Err(format!(
            "epoch {e}: cliques_containing_all([{u},{w}]) diverged"
        ));
    }
    // top-k: returned cliques must exist at this epoch and their sizes
    // must be the k largest sizes of the expected set
    let mut want_sizes: Vec<usize> = exp.iter().map(Vec::len).collect();
    want_sizes.sort_unstable_by(|a, b| b.cmp(a));
    want_sizes.truncate(obs.top.len());
    let got_sizes: Vec<usize> = obs.top.iter().map(Vec::len).collect();
    if got_sizes != want_sizes {
        return Err(format!(
            "epoch {e}: top-k sizes {got_sizes:?} != expected {want_sizes:?}"
        ));
    }
    for c in &obs.top {
        if !exp.contains(c) {
            return Err(format!("epoch {e}: top-k clique {c:?} not in C(G)"));
        }
    }
    if let Some((c, ok)) = &obs.sampled_maximal {
        if !ok {
            return Err(format!(
                "epoch {e}: snapshot served {c:?} but denies its maximality"
            ));
        }
        if !exp.contains(c) {
            return Err(format!("epoch {e}: served clique {c:?} not in C(G)"));
        }
    }
    Ok(())
}

/// Build an op schedule: all insert batches, interleaved with removals
/// of earlier batches that are later re-inserted (so removal epochs are
/// exercised mid-stream), ending at the full graph.
fn build_ops(edges: &[Edge], batch: usize, churn_every: usize) -> Vec<Op<'_>> {
    let chunks: Vec<&[Edge]> = edges.chunks(batch).collect();
    let mut ops = Vec::new();
    for (i, &chunk) in chunks.iter().enumerate() {
        ops.push(Op::Insert(chunk));
        if (i + 1) % churn_every == 0 {
            ops.push(Op::Remove(chunk));
            ops.push(Op::Insert(chunk));
        }
    }
    ops
}

fn run_interleaved(algo: DynAlgo, seed: u64) {
    let g = generators::gnp(15, 0.4, seed);
    let stream = EdgeStream::permuted(&g, seed ^ 0xabcd);
    let ops = build_ops(&stream.edges, 6, 3);
    let expected = expected_per_epoch(stream.n, &ops);

    let mut svc = CliqueService::wrap(
        parmce::session::DynamicSession::from_empty(stream.n, algo).with_threads(2),
    );
    let handle = svc.handle();
    let pool = ThreadPool::new(2);
    let stop = Arc::new(AtomicBool::new(false));
    let observations: Arc<Mutex<Vec<Observation>>> = Arc::new(Mutex::new(Vec::new()));
    let n = stream.n;

    pool.scope(|s| {
        for r in 0..2u64 {
            let mut reader = handle.reader();
            let stop = Arc::clone(&stop);
            let observations = Arc::clone(&observations);
            s.spawn(move |_| {
                let mut rng = Rng::new(seed ^ (r + 1) * 0x9e37);
                // do-while: at least one observation per reader, even if
                // the task is first scheduled after the writer finished
                loop {
                    let snap = Arc::clone(reader.current());
                    let obs = observe(&snap, &mut rng, n);
                    {
                        let mut log = observations.lock().unwrap();
                        if log.len() < 20_000 {
                            log.push(obs);
                        }
                    }
                    if stop.load(Ordering::Acquire) {
                        break;
                    }
                }
            });
        }
        // writer: apply every op; each publishes one epoch
        for op in &ops {
            match op {
                Op::Insert(edges) => {
                    svc.apply_batch(edges);
                }
                Op::Remove(edges) => {
                    svc.remove_batch(edges);
                }
            }
        }
        stop.store(true, Ordering::Release);
    });

    assert_eq!(svc.published_epoch(), ops.len() as u64);

    // 1. every concurrent answer was exact for its tagged epoch
    let observations = observations.lock().unwrap();
    assert!(
        !observations.is_empty(),
        "readers must have observed something"
    );
    for obs in observations.iter() {
        if let Err(e) = check_observation(obs, &expected) {
            panic!("snapshot isolation violated ({}): {e}", algo.name());
        }
    }

    // 2. final state: equals from-scratch enumeration of the full graph
    let final_snap = svc.snapshot();
    final_snap.validate().unwrap();
    let want = oracle_set(&g);
    let got: CliqueSet = final_snap.canonical_cliques().into_iter().collect();
    assert_eq!(got, want, "final C(G) diverged from scratch");

    // 3. the incrementally maintained index equals a full rebuild
    let rebuilt = svc.rebuilt_snapshot();
    rebuilt.validate().unwrap();
    assert_eq!(
        final_snap.canonical_cliques(),
        rebuilt.canonical_cliques()
    );
    for v in 0..n as Vertex {
        let mut a: Vec<Vec<Vertex>> = final_snap
            .cliques_containing(v)
            .iter()
            .map(|c| c.to_vec())
            .collect();
        let mut b: Vec<Vec<Vertex>> = rebuilt
            .cliques_containing(v)
            .iter()
            .map(|c| c.to_vec())
            .collect();
        a.sort();
        b.sort();
        assert_eq!(a, b, "incremental postings diverge from rebuild at {v}");
    }
    assert_eq!(
        final_snap.size_histogram().nonzero_bins(),
        rebuilt.size_histogram().nonzero_bins()
    );
}

#[test]
fn interleaved_queries_are_snapshot_isolated_sequential() {
    run_interleaved(DynAlgo::Imce, 101);
}

#[test]
fn interleaved_queries_are_snapshot_isolated_parallel() {
    run_interleaved(DynAlgo::ParImce, 202);
}

#[test]
fn every_epoch_prefix_is_exactly_servable() {
    // single-threaded variant: query *every* epoch right after its
    // publish and demand exactness — locks in the per-epoch expected
    // semantics the concurrent test samples from
    let g = generators::gnp(13, 0.45, 77);
    let stream = EdgeStream::permuted(&g, 3);
    let ops = build_ops(&stream.edges, 5, 4);
    let expected = expected_per_epoch(stream.n, &ops);

    let mut svc = CliqueService::from_empty(stream.n, DynAlgo::Imce);
    let mut rng = Rng::new(9);
    let handle = svc.handle();
    // epoch 0 (bootstrap) as well
    let obs = observe(&handle.snapshot(), &mut rng, stream.n);
    check_observation(&obs, &expected).unwrap();
    for (i, op) in ops.iter().enumerate() {
        match op {
            Op::Insert(edges) => {
                svc.apply_batch(edges);
            }
            Op::Remove(edges) => {
                svc.remove_batch(edges);
            }
        }
        let snap = handle.snapshot();
        assert_eq!(snap.epoch(), (i + 1) as u64);
        snap.validate().unwrap();
        let obs = observe(&snap, &mut rng, stream.n);
        check_observation(&obs, &expected).unwrap();
        let exp = &expected[i + 1];
        assert_eq!(snap.count(), exp.len(), "epoch {}", i + 1);
    }
}

#[test]
fn readers_pinned_to_old_snapshots_stay_correct() {
    // a reader that never revalidates keeps answering at its epoch even
    // as the writer races ahead — the copy-on-publish guarantee
    let g = generators::gnp(12, 0.5, 5);
    let stream = EdgeStream::permuted(&g, 6);
    let ops = build_ops(&stream.edges, 4, 100);
    let expected = expected_per_epoch(stream.n, &ops);

    let mut svc = CliqueService::from_empty(stream.n, DynAlgo::Imce);
    let mut pinned: Vec<Arc<CliqueSnapshot>> = vec![svc.snapshot()];
    for op in &ops {
        match op {
            Op::Insert(edges) => {
                svc.apply_batch(edges);
            }
            Op::Remove(edges) => {
                svc.remove_batch(edges);
            }
        }
        pinned.push(svc.snapshot());
    }
    let mut rng = Rng::new(31);
    for snap in &pinned {
        let obs = observe(snap, &mut rng, stream.n);
        check_observation(&obs, &expected).unwrap();
    }
}
