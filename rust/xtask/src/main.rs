//! Repo-local tooling. Two commands today:
//!
//! ```text
//! cargo xtask lint-invariants [--root <repo-root>]
//! cargo xtask check-prom <file>
//! ```
//!
//! `check-prom` validates a Prometheus text-exposition dump produced by
//! `parmce enumerate/serve-replay --metrics-out` (metric/label name
//! syntax, TYPE declarations, histogram bucket monotonicity) — the CI
//! gate for the telemetry export surface.
//!
//! `lint-invariants` enforces the crate's concurrency-correctness
//! invariants (ISSUE 6) over
//! `rust/src` (+ `rust/tests` for the SAFETY rule):
//!
//! 1. **unsafe-needs-safety** — every `unsafe` keyword site (block, fn,
//!    impl) must carry a `// SAFETY:` comment (same line or within the
//!    few preceding lines, attributes skipped) or a `# Safety` doc
//!    section.
//! 2. **sync-layer-only** — `std::sync::` / `core::sync::` paths may
//!    appear only in the swappable sync layer (`util/sync.rs` and its
//!    loom shim `util/loom_shim.rs`); everything else must import from
//!    `crate::util::sync` so the loom build swaps every primitive.
//! 3. **no-stray-relaxed** — `Ordering::Relaxed` is allowed only in the
//!    allowlisted statistics/hint files (see [`RELAXED_ALLOWLIST`]);
//!    anywhere else it must be justified and allowlisted, or upgraded.
//! 4. **no-lock-unwrap** (ISSUE 9) — `.lock().unwrap()` / `.lock().expect(`
//!    may appear only in the sync seam (see [`LOCK_UNWRAP_ALLOWLIST`]).
//!    Everywhere else locks go through `crate::util::sync::plock`, which
//!    recovers poisoned guards: panic safety is enforced structurally at
//!    the pool's job boundary, so poison `unwrap`s would only turn one
//!    contained panic into a crate-wide cascade.
//!
//! The offline toolchain cannot vendor `syn`, so this is a line-oriented
//! scanner: it strips `//` comments, `/* */` blocks and string literals
//! before matching, which covers every idiom used in this tree.  It
//! cannot see through `macro_rules!` expansion — none of the lint targets
//! are macro-generated here.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Files (relative to the repo root, `/`-separated) that re-export or wrap
/// `std::sync` — the entire sanctioned surface for rule 2.
const SYNC_LAYER_FILES: &[&str] = &["rust/src/util/sync.rs", "rust/src/util/loom_shim.rs"];

/// Files allowed to use `Ordering::Relaxed`, each with the reason the
/// relaxation is sound (printed by `--explain-allowlist`).
const RELAXED_ALLOWLIST: &[(&str, &str)] = &[
    (
        "rust/src/coordinator/pool.rs",
        "pending-counter decrement is a wakeup hint (mutex publishes jobs); steal/spawn stats; \
         telemetry mirrors (depth gauge, dequeue/wakeup counters) inherit the same argument — \
         see the PoolState memory-ordering contract",
    ),
    (
        "rust/src/telemetry/metrics.rs",
        "per-worker metric shards: Relaxed adds on private cache lines, Acquire sweep on \
         snapshot; exact only after a happens-before point (scope join), loom-modeled in \
         telemetry_counter_sweep_exact_after_join",
    ),
    (
        "rust/src/telemetry/subprob.rs",
        "per-root subproblem accumulators; read only after the enumeration scope joins",
    ),
    (
        "rust/src/mce/pivot.rs",
        "packed argmax fetch_max reduction; result read after the scope join",
    ),
    (
        "rust/src/mce/sink/core.rs",
        "monotone clique counter; exact only at quiescent points",
    ),
    (
        "rust/src/mce/sink/sharded.rs",
        "per-worker shard counters; merged after the scope join",
    ),
    (
        "rust/src/mce/sink/stats.rs",
        "histogram bins are independent monotone counters",
    ),
    (
        "rust/src/mce/sink/writer.rs",
        "byte/clique/flush counters and sticky-failure flag; budgets are soft caps",
    ),
    (
        "rust/src/util/membudget.rs",
        "used/peak accounting; the budget is advisory, not a publication edge",
    ),
    (
        "rust/src/graph/snapshot.rs",
        "epoch_hint is a monitoring-only staleness probe; the publish handoff is the Release store + Acquire load pair in GraphCell",
    ),
    (
        "rust/src/graph/degeneracy.rs",
        "level-peel degree decrements: crossings are claimed exactly once by the unique \
         fetch_sub return value, and core/order arrays are written on the caller thread \
         between scope joins",
    ),
    (
        "rust/src/service/driver.rs",
        "visibility-latency sampling boards and reader totals; read after join",
    ),
    (
        "rust/src/baselines/peamc.rs",
        "one-way cooperative timeout flag; no data published through it",
    ),
    (
        "rust/src/util/loom_shim.rs",
        "scheduler-PRNG bookkeeping inside the instrumentation itself",
    ),
];

/// Files allowed to `.lock().unwrap()` / `.lock().expect(`, each with the
/// reason (printed by `--explain-allowlist`).  Everything else uses the
/// poison-immune `plock`/`pwait_timeout` wrappers (ISSUE 9 rule
/// `no-lock-unwrap`).
const LOCK_UNWRAP_ALLOWLIST: &[(&str, &str)] = &[
    (
        "rust/src/util/sync.rs",
        "the seam that defines the poison policy: plock/pwait_timeout unwrap \
         LockResult by recovering the guard, so a raw lock() here is the \
         implementation, not a bypass",
    ),
    (
        "rust/src/util/loom_shim.rs",
        "instrumented lock wrappers mirror std's LockResult surface; the shim \
         is the other half of the sync seam",
    ),
];

/// Cap on how many lines above an `unsafe` site are scanned for the
/// `// SAFETY:` / `# Safety` marker; the scan also stops at the first
/// code line, so this only bounds runaway doc blocks.
const SAFETY_LOOKBACK: usize = 40;

#[derive(Debug)]
struct Violation {
    file: PathBuf,
    line: usize,
    rule: &'static str,
    message: String,
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cmd = None;
    let mut root = None;
    let mut operands = Vec::new();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--root" => {
                i += 1;
                root = args.get(i).map(PathBuf::from);
            }
            "--explain-allowlist" => {
                println!("# no-stray-relaxed");
                for (file, why) in RELAXED_ALLOWLIST {
                    println!("{file}: {why}");
                }
                println!("# no-lock-unwrap");
                for (file, why) in LOCK_UNWRAP_ALLOWLIST {
                    println!("{file}: {why}");
                }
                return ExitCode::SUCCESS;
            }
            c if cmd.is_none() => cmd = Some(c.to_string()),
            operand if !operand.starts_with('-') => operands.push(operand.to_string()),
            other => {
                eprintln!("xtask: unexpected argument `{other}`");
                return ExitCode::FAILURE;
            }
        }
        i += 1;
    }
    match cmd.as_deref() {
        Some("lint-invariants") => {
            let root = root.unwrap_or_else(repo_root);
            match lint_invariants(&root) {
                Ok(violations) if violations.is_empty() => {
                    println!("lint-invariants: clean");
                    ExitCode::SUCCESS
                }
                Ok(violations) => {
                    for v in &violations {
                        eprintln!(
                            "{}:{}: [{}] {}",
                            v.file.display(),
                            v.line,
                            v.rule,
                            v.message
                        );
                    }
                    eprintln!("lint-invariants: {} violation(s)", violations.len());
                    ExitCode::FAILURE
                }
                Err(e) => {
                    eprintln!("lint-invariants: {e}");
                    ExitCode::FAILURE
                }
            }
        }
        Some("check-prom") => {
            let Some(file) = operands.first() else {
                eprintln!("usage: cargo xtask check-prom <exposition-file>");
                return ExitCode::FAILURE;
            };
            let src = match std::fs::read_to_string(file) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("check-prom: cannot read {file}: {e}");
                    return ExitCode::FAILURE;
                }
            };
            match check_prometheus(&src) {
                Ok(stats) => {
                    println!(
                        "check-prom: {file} ok ({} metrics, {} samples)",
                        stats.metrics, stats.samples
                    );
                    ExitCode::SUCCESS
                }
                Err(errors) => {
                    for e in &errors {
                        eprintln!("{file}: {e}");
                    }
                    eprintln!("check-prom: {} error(s)", errors.len());
                    ExitCode::FAILURE
                }
            }
        }
        _ => {
            eprintln!(
                "usage: cargo xtask lint-invariants [--root <repo-root>] [--explain-allowlist]\n       cargo xtask check-prom <exposition-file>"
            );
            ExitCode::FAILURE
        }
    }
}

/// Summary returned by a clean [`check_prometheus`] pass.
struct PromStats {
    metrics: usize,
    samples: usize,
}

/// Validate a Prometheus text-exposition document: metric/label name
/// syntax, `# TYPE` declarations preceding their samples, parseable
/// values, and histogram structure (`le` labels, a `+Inf` bucket whose
/// cumulative count equals `_count`, monotone buckets).
///
/// This is deliberately a *format* checker, not a scrape simulator — it
/// gates the `--metrics-out` export surface in CI without needing a
/// Prometheus binary in the container.
fn check_prometheus(src: &str) -> Result<PromStats, Vec<String>> {
    let mut errors = Vec::new();
    // metric name -> declared type
    let mut types: Vec<(String, String)> = Vec::new();
    let mut samples = 0usize;
    // histogram bookkeeping: (metric, +Inf seen, last cumulative, count value)
    let mut hist: Vec<(String, Option<u64>, Option<u64>, Option<u64>)> = Vec::new();

    for (idx, raw) in src.lines().enumerate() {
        let lineno = idx + 1;
        let line = raw.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut parts = rest.splitn(2, ' ');
            let name = parts.next().unwrap_or("");
            let kind = parts.next().unwrap_or("").trim();
            if !is_metric_name(name) {
                errors.push(format!("line {lineno}: bad metric name in TYPE: `{name}`"));
            }
            if !matches!(kind, "counter" | "gauge" | "histogram" | "summary" | "untyped") {
                errors.push(format!("line {lineno}: unknown metric type `{kind}`"));
            }
            if types.iter().any(|(n, _)| n == name) {
                errors.push(format!("line {lineno}: duplicate TYPE for `{name}`"));
            }
            types.push((name.to_string(), kind.to_string()));
            if kind == "histogram" {
                hist.push((name.to_string(), None, None, None));
            }
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split(' ').next().unwrap_or("");
            if !is_metric_name(name) {
                errors.push(format!("line {lineno}: bad metric name in HELP: `{name}`"));
            }
            continue;
        }
        if line.starts_with('#') {
            continue; // free-form comment
        }

        // sample line: name[{labels}] value
        let (name_labels, value_str) = match line.rsplit_once(' ') {
            Some(p) => p,
            None => {
                errors.push(format!("line {lineno}: sample has no value: `{line}`"));
                continue;
            }
        };
        let value = parse_prom_value(value_str);
        if value.is_none() {
            errors.push(format!("line {lineno}: unparseable value `{value_str}`"));
        }
        let (name, labels) = match name_labels.split_once('{') {
            Some((n, rest)) => match rest.strip_suffix('}') {
                Some(body) => (n, parse_labels(body, lineno, &mut errors)),
                None => {
                    errors.push(format!("line {lineno}: unterminated label set"));
                    (n, Vec::new())
                }
            },
            None => (name_labels, Vec::new()),
        };
        if !is_metric_name(name) {
            errors.push(format!("line {lineno}: bad sample metric name `{name}`"));
            continue;
        }
        samples += 1;

        // Resolve against a TYPE declaration: exact match, or a histogram
        // series suffix.
        let base = ["_bucket", "_sum", "_count"]
            .iter()
            .find_map(|s| name.strip_suffix(s))
            .filter(|b| types.iter().any(|(n, k)| n == b && k == "histogram"));
        let declared = base.or_else(|| {
            types
                .iter()
                .find(|(n, _)| n == name)
                .map(|(n, _)| n.as_str())
        });
        let Some(base_name) = declared else {
            errors.push(format!(
                "line {lineno}: sample `{name}` has no preceding TYPE declaration"
            ));
            continue;
        };

        if let Some(entry) = hist.iter_mut().find(|(n, ..)| n == base_name) {
            let cum = value.map(|v| v as u64);
            if name.ends_with("_bucket") {
                let le = labels.iter().find(|(k, _)| k == "le").map(|(_, v)| v.as_str());
                match le {
                    None => errors.push(format!(
                        "line {lineno}: histogram bucket for `{base_name}` missing `le` label"
                    )),
                    Some("+Inf") => entry.1 = cum,
                    Some(_) => {
                        if let (Some(prev), Some(cur)) = (entry.2, cum) {
                            if cur < prev {
                                errors.push(format!(
                                    "line {lineno}: histogram `{base_name}` buckets not cumulative ({cur} < {prev})"
                                ));
                            }
                        }
                        entry.2 = cum;
                    }
                }
            } else if name.ends_with("_count") {
                entry.3 = cum;
            }
        }
    }

    for (name, inf, _, count) in &hist {
        match (inf, count) {
            (None, _) => errors.push(format!("histogram `{name}` has no `+Inf` bucket")),
            (Some(i), Some(c)) if i != c => errors.push(format!(
                "histogram `{name}`: +Inf bucket {i} != _count {c}"
            )),
            _ => {}
        }
    }

    if errors.is_empty() {
        Ok(PromStats {
            metrics: types.len(),
            samples,
        })
    } else {
        Err(errors)
    }
}

fn is_metric_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

fn is_label_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
}

fn parse_prom_value(s: &str) -> Option<f64> {
    match s {
        "+Inf" => Some(f64::INFINITY),
        "-Inf" => Some(f64::NEG_INFINITY),
        "NaN" => Some(f64::NAN),
        other => other.parse().ok(),
    }
}

/// Parse `k="v",k2="v2"` label bodies; escape sequences `\\`, `\"`, `\n`
/// are accepted inside values.
fn parse_labels(body: &str, lineno: usize, errors: &mut Vec<String>) -> Vec<(String, String)> {
    let mut out = Vec::new();
    let mut rest = body;
    while !rest.is_empty() {
        let Some(eq) = rest.find('=') else {
            errors.push(format!("line {lineno}: label without `=` in `{rest}`"));
            return out;
        };
        let key = rest[..eq].trim().to_string();
        if !is_label_name(&key) {
            errors.push(format!("line {lineno}: bad label name `{key}`"));
        }
        rest = &rest[eq + 1..];
        if !rest.starts_with('"') {
            errors.push(format!("line {lineno}: label value for `{key}` not quoted"));
            return out;
        }
        rest = &rest[1..];
        let mut value = String::new();
        let mut chars = rest.char_indices();
        let mut end = None;
        while let Some((i, c)) = chars.next() {
            match c {
                '\\' => match chars.next() {
                    Some((_, e @ ('\\' | '"'))) => value.push(e),
                    Some((_, 'n')) => value.push('\n'),
                    _ => {
                        errors.push(format!("line {lineno}: bad escape in label `{key}`"));
                    }
                },
                '"' => {
                    end = Some(i);
                    break;
                }
                other => value.push(other),
            }
        }
        let Some(end) = end else {
            errors.push(format!("line {lineno}: unterminated label value for `{key}`"));
            return out;
        };
        out.push((key, value));
        rest = &rest[end + 1..];
        rest = rest.strip_prefix(',').unwrap_or(rest).trim_start();
    }
    out
}

/// Repo root relative to this crate (rust/xtask → ../..).
fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .ancestors()
        .nth(2)
        .expect("xtask lives two levels below the repo root")
        .to_path_buf()
}

fn lint_invariants(root: &Path) -> Result<Vec<Violation>, String> {
    let mut files = Vec::new();
    collect_rs_files(&root.join("rust/src"), &mut files)?;
    let mut test_files = Vec::new();
    collect_rs_files(&root.join("rust/tests"), &mut test_files)?;

    let mut violations = Vec::new();
    for f in &files {
        let rel = relative_key(root, f);
        let src = std::fs::read_to_string(f).map_err(|e| format!("{}: {e}", f.display()))?;
        violations.extend(lint_source(f, &rel, &src, true));
    }
    for f in &test_files {
        let rel = relative_key(root, f);
        let src = std::fs::read_to_string(f).map_err(|e| format!("{}: {e}", f.display()))?;
        // tests: SAFETY rule only — they may stress std::sync directly
        violations.extend(lint_source(f, &rel, &src, false));
    }
    Ok(violations)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries = std::fs::read_dir(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| e.to_string())?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    out.sort();
    Ok(())
}

/// `root`-relative `/`-separated path for allowlist matching.
fn relative_key(root: &Path, file: &Path) -> String {
    file.strip_prefix(root)
        .unwrap_or(file)
        .components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

/// Lint one file. `full` enables the sync-layer and Relaxed rules (source
/// tree); `false` checks only the SAFETY rule (integration tests).
fn lint_source(file: &Path, rel: &str, src: &str, full: bool) -> Vec<Violation> {
    let raw_lines: Vec<&str> = src.lines().collect();
    let code_lines = strip_noncode(&raw_lines);
    let mut violations = Vec::new();

    for (idx, code) in code_lines.iter().enumerate() {
        let lineno = idx + 1;
        if has_word(code, "unsafe") && !safety_comment_near(&raw_lines, idx) {
            violations.push(Violation {
                file: file.to_path_buf(),
                line: lineno,
                rule: "unsafe-needs-safety",
                message: "`unsafe` without a `// SAFETY:` comment (same line or just above)"
                    .to_string(),
            });
        }
        if !full {
            continue;
        }
        if (code.contains("std::sync::") || code.contains("core::sync::"))
            && !SYNC_LAYER_FILES.contains(&rel)
        {
            violations.push(Violation {
                file: file.to_path_buf(),
                line: lineno,
                rule: "sync-layer-only",
                message: format!(
                    "direct `std::sync`/`core::sync` path outside the sync layer \
                     (import from crate::util::sync so `--cfg loom` can swap it): `{}`",
                    code.trim()
                ),
            });
        }
        if code.contains("Ordering::Relaxed")
            && !RELAXED_ALLOWLIST.iter().any(|(f, _)| f == &rel)
        {
            violations.push(Violation {
                file: file.to_path_buf(),
                line: lineno,
                rule: "no-stray-relaxed",
                message: "`Ordering::Relaxed` on a non-allowlisted atomic — justify and \
                          allowlist in rust/xtask, or use a stronger ordering"
                    .to_string(),
            });
        }
        if (code.contains(".lock().unwrap()") || code.contains(".lock().expect("))
            && !LOCK_UNWRAP_ALLOWLIST.iter().any(|(f, _)| f == &rel)
        {
            violations.push(Violation {
                file: file.to_path_buf(),
                line: lineno,
                rule: "no-lock-unwrap",
                message: "poison-cascading lock acquisition outside the sync seam — use \
                          `crate::util::sync::plock` (recovers poisoned guards; panic \
                          safety is enforced at the pool job boundary)"
                    .to_string(),
            });
        }
    }
    violations
}

/// Replace comments and string literals with spaces, line by line, keeping
/// line numbers stable.  Handles `//`, `/* ... */` (incl. multi-line),
/// `"..."` with escapes, and char literals enough to avoid false matches;
/// raw strings are treated as plain strings (good enough: no lint target
/// appears inside one in this tree).
fn strip_noncode(lines: &[&str]) -> Vec<String> {
    let mut out = Vec::with_capacity(lines.len());
    let mut in_block_comment = false;
    for line in lines {
        let bytes = line.as_bytes();
        let mut code = String::with_capacity(line.len());
        let mut i = 0;
        while i < bytes.len() {
            if in_block_comment {
                if bytes[i] == b'*' && i + 1 < bytes.len() && bytes[i + 1] == b'/' {
                    in_block_comment = false;
                    i += 2;
                } else {
                    i += 1;
                }
                continue;
            }
            match bytes[i] {
                b'/' if i + 1 < bytes.len() && bytes[i + 1] == b'/' => break, // rest is comment
                b'/' if i + 1 < bytes.len() && bytes[i + 1] == b'*' => {
                    in_block_comment = true;
                    i += 2;
                }
                b'"' => {
                    // skip string literal (with escapes)
                    i += 1;
                    while i < bytes.len() {
                        match bytes[i] {
                            b'\\' => i += 2,
                            b'"' => {
                                i += 1;
                                break;
                            }
                            _ => i += 1,
                        }
                    }
                    code.push(' ');
                }
                b'\'' if i + 2 < bytes.len()
                    && (bytes[i + 1] == b'\\' || bytes[i + 2] == b'\'') =>
                {
                    // char literal like 'x' or '\n' (not a lifetime)
                    i += 1;
                    while i < bytes.len() {
                        match bytes[i] {
                            b'\\' => i += 2,
                            b'\'' => {
                                i += 1;
                                break;
                            }
                            _ => i += 1,
                        }
                    }
                    code.push(' ');
                }
                c => {
                    code.push(c as char);
                    i += 1;
                }
            }
        }
        out.push(code);
    }
    out
}

/// True if `word` occurs in `code` as a standalone token (not part of a
/// longer identifier such as `unsafe_code`).
fn has_word(code: &str, word: &str) -> bool {
    let b = code.as_bytes();
    let mut start = 0;
    while let Some(pos) = code[start..].find(word) {
        let at = start + pos;
        let before_ok = at == 0 || !is_ident_byte(b[at - 1]);
        let after = at + word.len();
        let after_ok = after >= b.len() || !is_ident_byte(b[after]);
        if before_ok && after_ok {
            return true;
        }
        start = at + word.len();
    }
    false
}

fn is_ident_byte(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

/// A SAFETY (or `# Safety` doc) marker on the same raw line, or in the
/// contiguous run of comment/attribute/blank lines directly above the
/// `unsafe` site (doc blocks included).  The scan stops at the first code
/// line — the convention this enforces is "the justification sits
/// immediately above the unsafe site".
fn safety_comment_near(raw_lines: &[&str], idx: usize) -> bool {
    if raw_lines[idx].contains("SAFETY:") || raw_lines[idx].contains("# Safety") {
        return true;
    }
    let mut i = idx;
    let mut scanned = 0;
    while i > 0 && scanned < SAFETY_LOOKBACK {
        i -= 1;
        scanned += 1;
        let l = raw_lines[i].trim();
        if l.contains("SAFETY:") || l.contains("# Safety") {
            return true;
        }
        let is_comment = l.starts_with("//"); // covers `//`, `///`, `//!`
        let is_attr = l.starts_with("#[") || l.starts_with("#![");
        if !(l.is_empty() || is_comment || is_attr) {
            return false; // hit real code: the site has no adjacent marker
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint_str(src: &str, rel: &str, full: bool) -> Vec<Violation> {
        lint_source(Path::new(rel), rel, src, full)
    }

    #[test]
    fn clean_unsafe_with_safety_comment_passes() {
        let src = "// SAFETY: pointer outlives the scope\nlet x = unsafe { &*p };\n";
        assert!(lint_str(src, "rust/src/a.rs", true).is_empty());
    }

    #[test]
    fn seeded_violation_unsafe_without_safety_fails() {
        // the acceptance-criteria check: a bare unsafe block must trip
        let src = "fn f(p: *const u32) -> u32 {\n    unsafe { *p }\n}\n";
        let v = lint_str(src, "rust/src/a.rs", true);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "unsafe-needs-safety");
        assert_eq!(v[0].line, 2);
    }

    #[test]
    fn safety_doc_section_counts() {
        let src = "/// # Safety\n/// caller promises p is valid\npub unsafe fn f(p: *const u32) {}\n";
        assert!(lint_str(src, "rust/src/a.rs", true).is_empty());
    }

    #[test]
    fn attributes_do_not_break_the_lookback() {
        let src = "// SAFETY: witness contract\n#[allow(unsafe_code)]\nlet s = unsafe { S::new() };\n";
        assert!(lint_str(src, "rust/src/a.rs", true).is_empty());
    }

    #[test]
    fn unsafe_inside_comments_and_strings_ignored() {
        let src = "// this mentions unsafe code\nlet s = \"unsafe\";\nlet l = 'u';\n/* unsafe\n   unsafe */\n";
        assert!(lint_str(src, "rust/src/a.rs", true).is_empty());
    }

    #[test]
    fn unsafe_as_identifier_fragment_ignored() {
        let src = "#![deny(unsafe_op_in_unsafe_fn)]\n#![warn(unsafe_code)]\n";
        assert!(lint_str(src, "rust/src/lib.rs", true).is_empty());
    }

    #[test]
    fn std_sync_import_flagged_outside_sync_layer() {
        let src = "use std::sync::Mutex;\n";
        let v = lint_str(src, "rust/src/coordinator/pool.rs", true);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "sync-layer-only");
        // ... but sanctioned inside the layer itself
        assert!(lint_str(src, "rust/src/util/sync.rs", true).is_empty());
        assert!(lint_str(src, "rust/src/util/loom_shim.rs", true).is_empty());
    }

    #[test]
    fn relaxed_ordering_flagged_unless_allowlisted() {
        let src = "x.store(1, Ordering::Relaxed);\n";
        let v = lint_str(src, "rust/src/service/snapshot.rs", true);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "no-stray-relaxed");
        assert!(lint_str(src, "rust/src/mce/sink/stats.rs", true).is_empty());
    }

    #[test]
    fn lock_unwrap_flagged_outside_sync_seam() {
        for src in [
            "let g = self.shards[i].lock().unwrap();\n",
            "let g = m.lock().expect(\"poisoned\");\n",
        ] {
            let v = lint_str(src, "rust/src/util/chashmap.rs", true);
            assert_eq!(v.len(), 1, "{src:?} -> {v:?}");
            assert_eq!(v[0].rule, "no-lock-unwrap");
            // ... but sanctioned inside the seam that defines the policy
            assert!(lint_str(src, "rust/src/util/sync.rs", true).is_empty());
            assert!(lint_str(src, "rust/src/util/loom_shim.rs", true).is_empty());
        }
        // plock and into_inner are the sanctioned spellings everywhere
        let src = "let g = plock(&m);\nlet v = m.into_inner().unwrap();\n";
        assert!(lint_str(src, "rust/src/util/chashmap.rs", true).is_empty());
        // mentions in comments/strings don't trip the rule
        let src = "// forbid .lock().unwrap() here\nlet s = \".lock().expect(\";\n";
        assert!(lint_str(src, "rust/src/util/chashmap.rs", true).is_empty());
    }

    #[test]
    fn tests_only_check_safety_rule() {
        let src = "use std::sync::Mutex;\nx.load(Ordering::Relaxed);\nlet g = m.lock().unwrap();\n";
        assert!(lint_str(src, "rust/tests/t.rs", false).is_empty());
        let src = "unsafe { *p }\n";
        assert_eq!(lint_str(src, "rust/tests/t.rs", false).len(), 1);
    }

    #[test]
    fn whole_tree_is_clean() {
        // the real repo must pass its own lint (acceptance criterion);
        // this runs in `cargo test` so the default check step gates it
        let violations = lint_invariants(&repo_root()).expect("scan repo");
        assert!(
            violations.is_empty(),
            "lint-invariants violations:\n{:#?}",
            violations
        );
    }

    #[test]
    fn seeded_violation_in_temp_tree_fails_end_to_end() {
        // build a fake repo root with one dirty file and run the full scan
        let root = std::env::temp_dir().join(format!("xtask_lint_{}", std::process::id()));
        let src_dir = root.join("rust/src");
        let test_dir = root.join("rust/tests");
        std::fs::create_dir_all(&src_dir).unwrap();
        std::fs::create_dir_all(&test_dir).unwrap();
        std::fs::write(
            src_dir.join("bad.rs"),
            "pub fn f(p: *const u8) -> u8 { unsafe { *p } }\n",
        )
        .unwrap();
        let violations = lint_invariants(&root).unwrap();
        std::fs::remove_dir_all(&root).unwrap();
        assert_eq!(violations.len(), 1, "{violations:?}");
        assert_eq!(violations[0].rule, "unsafe-needs-safety");
    }

    // --- check-prom ---

    #[test]
    fn valid_exposition_passes() {
        let src = "\
# HELP parmce_cliques_emitted_total Maximal cliques emitted.
# TYPE parmce_cliques_emitted_total counter
parmce_cliques_emitted_total 42
# TYPE parmce_pool_worker_busy_ns_total counter
parmce_pool_worker_busy_ns_total{worker=\"0\"} 100
parmce_pool_worker_busy_ns_total{worker=\"external\"} 7
# TYPE parmce_pool_queue_depth gauge
parmce_pool_queue_depth 0
# TYPE parmce_dynamic_batch_ns histogram
parmce_dynamic_batch_ns_bucket{le=\"1023\"} 1
parmce_dynamic_batch_ns_bucket{le=\"2047\"} 3
parmce_dynamic_batch_ns_bucket{le=\"+Inf\"} 4
parmce_dynamic_batch_ns_sum 5000
parmce_dynamic_batch_ns_count 4
";
        let stats = check_prometheus(src).expect("valid exposition");
        assert_eq!(stats.metrics, 4);
        assert_eq!(stats.samples, 9);
    }

    #[test]
    fn sample_without_type_declaration_fails() {
        let err = check_prometheus("parmce_orphan_total 1\n").unwrap_err();
        assert!(err[0].contains("no preceding TYPE"), "{err:?}");
    }

    #[test]
    fn bad_names_values_and_labels_fail() {
        let err = check_prometheus("# TYPE 9bad counter\n").unwrap_err();
        assert!(err.iter().any(|e| e.contains("bad metric name")), "{err:?}");
        let err =
            check_prometheus("# TYPE ok counter\nok notanumber\n").unwrap_err();
        assert!(err.iter().any(|e| e.contains("unparseable value")), "{err:?}");
        let err =
            check_prometheus("# TYPE ok counter\nok{9bad=\"v\"} 1\n").unwrap_err();
        assert!(err.iter().any(|e| e.contains("bad label name")), "{err:?}");
        let err = check_prometheus("# TYPE ok counter\nok{l=unquoted} 1\n").unwrap_err();
        assert!(err.iter().any(|e| e.contains("not quoted")), "{err:?}");
        let err = check_prometheus("# TYPE ok wrongkind\n").unwrap_err();
        assert!(err.iter().any(|e| e.contains("unknown metric type")), "{err:?}");
    }

    #[test]
    fn histogram_structure_is_enforced() {
        // missing +Inf bucket
        let err = check_prometheus(
            "# TYPE h histogram\nh_bucket{le=\"1\"} 1\nh_sum 1\nh_count 1\n",
        )
        .unwrap_err();
        assert!(err.iter().any(|e| e.contains("no `+Inf` bucket")), "{err:?}");
        // non-cumulative buckets
        let err = check_prometheus(
            "# TYPE h histogram\nh_bucket{le=\"1\"} 5\nh_bucket{le=\"3\"} 2\nh_bucket{le=\"+Inf\"} 5\nh_sum 9\nh_count 5\n",
        )
        .unwrap_err();
        assert!(err.iter().any(|e| e.contains("not cumulative")), "{err:?}");
        // +Inf disagrees with _count
        let err = check_prometheus(
            "# TYPE h histogram\nh_bucket{le=\"+Inf\"} 4\nh_sum 9\nh_count 5\n",
        )
        .unwrap_err();
        assert!(err.iter().any(|e| e.contains("!= _count")), "{err:?}");
    }

    #[test]
    fn label_escapes_parse() {
        let src = "# TYPE ok counter\nok{l=\"a\\\\b\\\"c\\nd\"} 1\n";
        assert!(check_prometheus(src).is_ok());
    }
}
