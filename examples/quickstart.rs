//! Quickstart: enumerate the maximal cliques of a small graph three ways —
//! sequential TTT, ParTTT, and ParMCE — and print them.
//!
//!     cargo run --release --example quickstart

use std::sync::Arc;

use parmce::coordinator::pool::ThreadPool;
use parmce::graph::csr::CsrGraph;
use parmce::mce::parmce::parmce;
use parmce::mce::parttt::parttt;
use parmce::mce::ranking::{RankStrategy, Ranking};
use parmce::mce::sink::{CliqueSink, CollectSink};
use parmce::mce::{ttt, ParMceConfig, ParTttConfig};

fn main() {
    // the paper's Figure 1-style example: a triangle sharing a vertex with
    // a square's diagonal braces
    let edges = [
        (0, 1), (1, 2), (0, 2),       // triangle {0,1,2}
        (2, 3), (3, 4), (2, 4),       // triangle {2,3,4}
        (4, 5), (5, 6), (4, 6), (3, 6), (3, 4), // dense tail
    ];
    let g = CsrGraph::from_edges(7, &edges);
    println!("graph: n={} m={}", g.n(), g.m());

    // 1. sequential TTT (Tomita et al. — the paper's baseline)
    let sink = CollectSink::new();
    ttt::ttt(&g, &sink);
    let seq = sink.into_canonical();
    println!("\nTTT found {} maximal cliques:", seq.len());
    for c in &seq {
        println!("  {c:?}");
    }

    // 2. ParTTT on the work-stealing pool
    let pool = ThreadPool::new(4);
    let ga = Arc::new(g.clone());
    let collect = Arc::new(CollectSink::new());
    let dyn_sink: Arc<dyn CliqueSink> = collect.clone();
    parttt(&pool, &ga, &dyn_sink, ParTttConfig::default());
    drop(dyn_sink);
    let par = Arc::try_unwrap(collect).ok().unwrap().into_canonical();
    assert_eq!(seq, par, "ParTTT must agree with TTT");
    println!("\nParTTT agrees ({} cliques).", par.len());

    // 3. ParMCE with degree ranking (the paper's best configuration)
    let ranking = Arc::new(Ranking::compute(&g, RankStrategy::Degree));
    let collect = Arc::new(CollectSink::new());
    let dyn_sink: Arc<dyn CliqueSink> = collect.clone();
    parmce(&pool, &ga, &ranking, &dyn_sink, ParMceConfig::default());
    drop(dyn_sink);
    let mce = Arc::try_unwrap(collect).ok().unwrap().into_canonical();
    assert_eq!(seq, mce, "ParMCE must agree with TTT");
    println!("ParMCEDegree agrees ({} cliques).", mce.len());

    let (spawned, steals) = pool.scheduler_counters();
    println!("\nscheduler: {spawned} tasks spawned, {steals} steals");
}
