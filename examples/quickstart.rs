//! Quickstart: enumerate the maximal cliques of a small graph three ways —
//! sequential TTT, ParTTT, and ParMCE — through one `MceSession`.
//!
//!     cargo run --release --example quickstart

use parmce::session::{Algo, MceSession, SinkSpec};

fn main() {
    // the paper's Figure 1-style example: a triangle sharing a vertex with
    // a square's diagonal braces
    let edges = [
        (0, 1), (1, 2), (0, 2),       // triangle {0,1,2}
        (2, 3), (3, 4), (2, 4),       // triangle {2,3,4}
        (4, 5), (5, 6), (4, 6), (3, 6), (3, 4), // dense tail
    ];
    let session = MceSession::builder()
        .edges(7, &edges)
        .algo(Algo::Ttt)
        .sink(SinkSpec::Collect)
        .threads(4)
        .build()
        .expect("session");
    let g = session.graph();
    println!("graph: n={} m={}", g.n(), g.m());

    // 1. sequential TTT (Tomita et al. — the paper's baseline)
    let run = session.run();
    let seq = run.cliques.expect("collect sink");
    println!("\nTTT found {} maximal cliques:", seq.len());
    for c in &seq {
        println!("  {c:?}");
    }

    // 2./3. the parallel algorithms — same session, same verbs
    for algo in [Algo::ParTtt, Algo::ParMce] {
        let (cliques, report) = session.collect(algo);
        assert_eq!(seq, cliques, "{} must agree with TTT", algo.name());
        println!(
            "{} agrees ({} cliques in {:?}).",
            algo.name(),
            report.cliques,
            report.wall
        );
    }

    let (spawned, steals) = session.pool().scheduler_counters();
    println!("\nscheduler: {spawned} tasks spawned, {steals} steals");
    println!("session history: {} runs recorded", session.history().len());
}
