//! Dynamic-graph example: maintain the maximal clique set of a growing
//! graph with IMCE (sequential) and ParIMCE (parallel) `DynamicSession`s,
//! batch by batch — the Figure 4 pipeline — then remove edges again
//! (decremental case).
//!
//!     cargo run --release --example dynamic_mce

use parmce::dynamic::stream::EdgeStream;
use parmce::graph::datasets::{Dataset, Scale};
use parmce::session::{Algo, DynAlgo, DynamicSession, MceSession};
use parmce::util::table::{fmt_count, fmt_secs, Table};

fn main() {
    let d = Dataset::CaCitHepThLike; // the paper's hardest dynamic case
    let g = d.graph(Scale::Tiny);
    println!(
        "dataset {} (n={}, m={}, density {:.3})",
        d.name(),
        g.n(),
        g.m(),
        g.density()
    );
    let stream = EdgeStream::permuted(&g, 1);
    let batch = 25;

    // sequential replay
    let mut seq = DynamicSession::from_empty(stream.n, DynAlgo::Imce);
    let seq_records = seq.replay(&stream, batch, Some(20));
    // parallel replay (must agree batch-by-batch)
    let mut par = DynamicSession::from_empty(stream.n, DynAlgo::ParImce).with_threads(4);
    let par_records = par.replay(&stream, batch, Some(20));

    let mut t = Table::new(
        "Per-batch incremental maintenance (IMCE vs ParIMCE)",
        &["batch", "new", "subsumed", "Δ", "IMCE", "ParIMCE(wall)"],
    );
    for (s, p) in seq_records.iter().zip(&par_records) {
        assert_eq!(s.new_cliques, p.new_cliques, "batch {} diverged", s.batch_index);
        assert_eq!(s.subsumed, p.subsumed);
        t.row(vec![
            s.batch_index.to_string(),
            fmt_count(s.new_cliques as u64),
            fmt_count(s.subsumed as u64),
            fmt_count(s.change_size() as u64),
            fmt_secs(s.ns as f64 / 1e9),
            fmt_secs(p.ns as f64 / 1e9),
        ]);
    }
    println!("{}", t.render());
    println!(
        "registry now tracks {} maximal cliques over {} edges ({} batches applied)",
        fmt_count(par.clique_count() as u64),
        fmt_count(par.graph().m() as u64),
        par.batches_applied()
    );

    // decremental: remove the last batch again
    let processed = batch * par_records.len().min(stream.edges.len() / batch);
    let last = &stream.edges[processed.saturating_sub(batch)..processed];
    let r = par.remove_batch(last);
    println!(
        "decremental: removing the last {} edges deleted {} cliques, surfaced {} replacements; registry {}",
        last.len(),
        r.subsumed.len(),
        r.new_cliques.len(),
        fmt_count(par.clique_count() as u64)
    );

    // verify against from-scratch enumeration through the static session
    let want = MceSession::builder()
        .graph(par.csr())
        .threads(1)
        .build()
        .expect("session")
        .count(Algo::Ttt)
        .cliques;
    assert_eq!(
        par.clique_count() as u64,
        want,
        "registry diverged from scratch"
    );
    println!("✓ registry verified against from-scratch TTT ({want} cliques)");
}
