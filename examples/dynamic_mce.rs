//! Dynamic-graph example: maintain the maximal clique set of a growing
//! graph with IMCE (sequential) and ParIMCE (parallel), batch by batch —
//! the Figure 4 pipeline — then remove edges again (decremental case).
//!
//!     cargo run --release --example dynamic_mce

use parmce::coordinator::pool::ThreadPool;
use parmce::dynamic::stream::{imce_remove_batch, replay, EdgeStream, Engine};
use parmce::graph::datasets::{Dataset, Scale};
use parmce::util::table::{fmt_count, fmt_secs, Table};

fn main() {
    let d = Dataset::CaCitHepThLike; // the paper's hardest dynamic case
    let g = d.graph(Scale::Tiny);
    println!(
        "dataset {} (n={}, m={}, density {:.3})",
        d.name(),
        g.n(),
        g.m(),
        g.density()
    );
    let stream = EdgeStream::permuted(&g, 1);
    let batch = 25;

    // sequential replay
    let (seq_records, _, _) = replay(&stream, batch, Engine::Sequential, Some(20));
    // parallel replay (must agree batch-by-batch)
    let pool = ThreadPool::new(4);
    let (par_records, mut graph, registry) =
        replay(&stream, batch, Engine::Parallel(&pool), Some(20));

    let mut t = Table::new(
        "Per-batch incremental maintenance (IMCE vs ParIMCE)",
        &["batch", "new", "subsumed", "Δ", "IMCE", "ParIMCE(wall)"],
    );
    for (s, p) in seq_records.iter().zip(&par_records) {
        assert_eq!(s.new_cliques, p.new_cliques, "batch {} diverged", s.batch_index);
        assert_eq!(s.subsumed, p.subsumed);
        t.row(vec![
            s.batch_index.to_string(),
            fmt_count(s.new_cliques as u64),
            fmt_count(s.subsumed as u64),
            fmt_count(s.change_size() as u64),
            fmt_secs(s.ns as f64 / 1e9),
            fmt_secs(p.ns as f64 / 1e9),
        ]);
    }
    println!("{}", t.render());
    println!(
        "registry now tracks {} maximal cliques over {} edges",
        fmt_count(registry.len() as u64),
        fmt_count(graph.m() as u64)
    );

    // decremental: remove the last batch again
    let processed = batch * par_records.len().min(stream.edges.len() / batch);
    let last = &stream.edges[processed.saturating_sub(batch)..processed];
    let r = imce_remove_batch(&mut graph, &registry, last);
    println!(
        "decremental: removing the last {} edges deleted {} cliques, surfaced {} replacements; registry {}",
        last.len(),
        r.subsumed.len(),
        r.new_cliques.len(),
        fmt_count(registry.len() as u64)
    );

    // verify against from-scratch enumeration
    let want = {
        let sink = parmce::mce::sink::CountSink::new();
        parmce::mce::ttt::ttt(&graph.to_csr(), &sink);
        sink.count()
    };
    assert_eq!(registry.len() as u64, want, "registry diverged from scratch");
    println!("✓ registry verified against from-scratch TTT ({want} cliques)");
}
