//! Stream enumeration results to disk — the output-dominated workload
//! the counting sinks cannot serve (Orkut's 2.27B maximal cliques fit on
//! disk, not in memory).  Each pool worker buffers into its own shard;
//! buffers flush to the file in ~64 KiB chunks, and an optional session
//! memory budget truncates the file honestly instead of filling the disk.
//!
//!     cargo run --release --example stream_cliques [tiny|small|full] [OUT.ndjson]

use parmce::graph::datasets::{Dataset, Scale};
use parmce::session::{Algo, MceSession, WriterFormat};
use parmce::util::table::fmt_count;

fn main() {
    let scale = match std::env::args().nth(1).as_deref() {
        Some("tiny") => Scale::Tiny,
        Some("full") => Scale::Full,
        _ => Scale::Small,
    };
    let out = std::env::args()
        .nth(2)
        .unwrap_or_else(|| "results/cliques.ndjson".into());
    if let Some(parent) = std::path::Path::new(&out).parent() {
        let _ = std::fs::create_dir_all(parent);
    }

    let d = Dataset::DblpLike; // the paper's large-clique case
    let g = d.graph(scale);
    println!("dataset {} (n={}, m={})", d.name(), g.n(), g.m());

    // 1. full streaming run: ParMCE on the pool, every clique to disk
    let session = MceSession::builder()
        .graph(g.clone())
        .algo(Algo::ParMce)
        .threads(4)
        .build()
        .expect("session");
    let (report, stats) = session
        .stream_to(Algo::ParMce, &out, WriterFormat::Ndjson)
        .expect("stream run");
    assert_eq!(stats.cliques, report.cliques, "writer lost cliques");
    assert_eq!(stats.dropped, 0);
    println!(
        "wrote {} cliques, {} bytes, {} flushes -> {out} ({:.0} cliques/s)",
        fmt_count(stats.cliques),
        fmt_count(stats.bytes),
        stats.flushes,
        report.cliques_per_sec(),
    );

    // cross-check against the sequential baseline
    let want = session.count(Algo::Ttt).cliques;
    assert_eq!(report.cliques, want, "ParMCE vs TTT");
    println!("verified against sequential TTT ({} cliques)", fmt_count(want));

    // 2. budgeted run: a session memory limit becomes the writer's byte
    //    budget — output truncates, enumeration still completes
    let capped = MceSession::builder()
        .graph(g)
        .threads(4)
        .mem_budget_bytes(1024)
        .build()
        .expect("session");
    let capped_out = format!("{out}.capped");
    let (capped_report, capped_stats) = capped
        .stream_to(Algo::ParMce, &capped_out, WriterFormat::Ndjson)
        .expect("capped stream run");
    assert_eq!(capped_report.cliques, want, "enumeration unaffected by cap");
    println!(
        "1 KiB budget: kept {} cliques ({} bytes), dropped {} -> {capped_out}",
        fmt_count(capped_stats.cliques),
        fmt_count(capped_stats.bytes),
        fmt_count(capped_stats.dropped),
    );
    let _ = std::fs::remove_file(&capped_out);
}
