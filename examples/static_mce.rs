//! End-to-end driver over the full three-layer stack (the repository's
//! headline example; its output is recorded in EXPERIMENTS.md):
//!
//!   1. build the five static dataset analogs (graph substrate),
//!   2. compute the ParMCETri vertex ranking on the **AOT Pallas kernel
//!      via PJRT** (L1/L2 artifacts — falls back to CPU if absent) and
//!      seed it into the session's ranking cache,
//!   3. enumerate with ParMCE on the work-stealing pool (L3),
//!   4. verify the count against sequential TTT,
//!   5. replay the measured task trace through the scheduler simulator
//!      and print Table-4-shaped rows (TTT vs ParTTT vs ParMCE @ 32).
//!
//!     make artifacts && cargo run --release --example static_mce

use std::sync::Arc;

use parmce::experiments::fixtures;
use parmce::graph::datasets::{Scale, STATIC_DATASETS};
use parmce::mce::ranking::{RankStrategy, Ranking};
use parmce::runtime::engine::Engine;
use parmce::runtime::tri_rank::PjrtTriangleBackend;
use parmce::session::{Algo, MceSession};
use parmce::util::table::{fmt_count, fmt_secs, fmt_speedup, Table};

fn main() {
    let scale = match std::env::args().nth(1).as_deref() {
        Some("tiny") => Scale::Tiny,
        Some("full") => Scale::Full,
        _ => Scale::Small,
    };
    let engine = Engine::load_default();
    match &engine {
        Ok(_) => println!("PJRT engine loaded — triangle ranking runs on the Pallas kernel"),
        Err(e) => println!("artifacts unavailable ({e}); CPU triangle ranking fallback"),
    }

    let mut table = Table::new(
        "End-to-end: TTT vs ParTTT vs ParMCETri (PJRT-ranked), 32 simulated workers",
        &[
            "Dataset", "cliques", "TTT(s)", "ParTTT@32", "ParMCETri@32",
            "speedup", "rank backend", "rank(s)",
        ],
    );
    let (mut spawned_total, mut steals_total) = (0u64, 0u64);

    for d in STATIC_DATASETS {
        let g = d.graph(scale);

        // L1/L2: triangle ranking — on the AOT kernel when available —
        // seeded into the session so every later run reuses it
        let mut builder = MceSession::builder()
            .graph(g.clone())
            .algo(Algo::ParMce)
            .rank_strategy(RankStrategy::Triangle)
            .threads(4);
        let (backend_name, rank_secs) = match &engine {
            Ok(e) => {
                let backend = PjrtTriangleBackend::new(e);
                let t0 = std::time::Instant::now();
                let r = Ranking::compute_with(&g, RankStrategy::Triangle, &backend)
                    .expect("PJRT ranking");
                builder = builder.ranking(Arc::new(r));
                ("pjrt-pallas", t0.elapsed().as_secs_f64())
            }
            Err(_) => {
                let t0 = std::time::Instant::now();
                let r = Ranking::compute(&g, RankStrategy::Triangle);
                builder = builder.ranking(Arc::new(r));
                ("cpu-forward", t0.elapsed().as_secs_f64())
            }
        };
        let session = builder.build().expect("session");

        // L3 baseline + simulated parallel runs
        let (seq_count, ttt_s) = fixtures::run_ttt(&session);
        let (c1, parttt_s) = fixtures::parttt_sim_secs(&session, 32);
        let (c2, parmce_s) = fixtures::parmce_sim_secs(&session, RankStrategy::Triangle, 32);
        assert_eq!(seq_count, c1, "{}: ParTTT count mismatch", d.name());
        assert_eq!(seq_count, c2, "{}: ParMCE count mismatch", d.name());

        // real pool execution must agree too (wall clock on 1 core)
        let wall = session.run();
        assert_eq!(
            seq_count, wall.report.cliques,
            "{}: pool run mismatch",
            d.name()
        );

        table.row(vec![
            d.name().into(),
            fmt_count(seq_count),
            fmt_secs(ttt_s),
            fmt_secs(parttt_s),
            fmt_secs(parmce_s),
            fmt_speedup(ttt_s / parmce_s),
            backend_name.into(),
            fmt_secs(rank_secs),
        ]);
        let (spawned, steals) = session.pool().scheduler_counters();
        spawned_total += spawned;
        steals_total += steals;
        println!(
            "✓ {}: {} maximal cliques verified across all layers",
            d.name(),
            fmt_count(seq_count)
        );
    }

    println!("\n{}", table.render());
    println!("scheduler counters: {spawned_total} tasks, {steals_total} steals");
}
