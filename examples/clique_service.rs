//! CliqueService example: maintain C(G) under a replayed edge stream and
//! query it through epoch-versioned snapshots — counts, per-vertex
//! lookups, index intersections, top-k, histogram, maximality checks —
//! then run the mixed update/query workload driver.
//!
//!     cargo run --release --example clique_service

use parmce::coordinator::pool::ThreadPool;
use parmce::dynamic::stream::EdgeStream;
use parmce::graph::datasets::{Dataset, Scale};
use parmce::service::{serve_replay, CliqueService, DriverConfig};
use parmce::session::{Algo, DynAlgo, MceSession};
use parmce::util::table::fmt_count;

fn main() {
    let d = Dataset::DblpLike;
    let g = d.graph(Scale::Tiny);
    println!("serving {} (n={}, m={})", d.name(), g.n(), g.m());
    let stream = EdgeStream::permuted(&g, 11);

    // --- grow the graph half-way, querying as epochs land ------------------
    let mut svc = CliqueService::from_empty(stream.n, DynAlgo::Imce);
    let half = (stream.edges.len() / 2).max(1);
    let records = svc.replay(&stream, 40, Some(half.div_ceil(40)));
    println!(
        "applied {} batches → epoch {}",
        records.len(),
        svc.published_epoch()
    );

    let h = svc.handle();
    let count = h.count();
    println!(
        "epoch {}: {} maximal cliques",
        count.epoch,
        fmt_count(count.value as u64)
    );
    let top = h.top_k_largest(3);
    for (i, c) in top.value.iter().enumerate() {
        println!("  top-{} size {}: {:?}", i + 1, c.len(), c);
        assert!(
            h.is_maximal_clique(c).value,
            "a served clique must be maximal"
        );
    }
    if let Some(largest) = top.value.first() {
        let v = largest[0];
        let containing = h.cliques_containing(v);
        println!(
            "vertex {v} sits in {} maximal cliques (epoch {})",
            containing.value.len(),
            containing.epoch
        );
        if largest.len() >= 2 {
            let pair = [largest[0], largest[1]];
            let both = h.cliques_containing_all(&pair);
            println!(
                "vertices {pair:?} share {} maximal cliques",
                both.value.len()
            );
            assert!(!both.value.is_empty(), "the top clique contains both");
        }
    }
    let hist = h.size_histogram();
    println!(
        "size histogram (epoch {}): {:?} (max size {})",
        hist.epoch,
        hist.value.nonzero_bins(),
        hist.value.max_size()
    );

    // --- serve the rest under concurrent readers ---------------------------
    let consumed = (records.len() * 40).min(stream.edges.len());
    let rest = EdgeStream {
        n: stream.n,
        edges: stream.edges[consumed..].to_vec(),
    };
    let cfg = DriverConfig {
        batch_size: 40,
        readers: 2,
        queries_per_round: 6,
        churn_every: Some(4),
        seed: 5,
        max_batches: None,
    };
    let pool = ThreadPool::new(cfg.readers);
    let report = serve_replay(&mut svc, &rest, &pool, &cfg);
    println!("driver: {}", report.summary());
    assert_eq!(report.consistency_violations, 0, "snapshot isolation held");

    // --- verify the served state against from-scratch enumeration ----------
    let want = MceSession::builder()
        .graph(svc.session().csr())
        .threads(1)
        .build()
        .expect("session")
        .count(Algo::Ttt)
        .cliques;
    let got = svc.handle().count();
    assert_eq!(got.value as u64, want, "served C(G) diverged from scratch");
    let rebuilt = svc.rebuilt_snapshot();
    assert_eq!(
        svc.snapshot().canonical_cliques(),
        rebuilt.canonical_cliques(),
        "incremental index diverged from rebuild"
    );
    println!(
        "✓ epoch {} verified against from-scratch TTT ({} cliques) and a full index rebuild",
        got.epoch,
        fmt_count(want)
    );
}
