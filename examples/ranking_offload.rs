//! L1/L2 offload showcase: per-vertex triangle counts (the ParMCETri
//! ranking metric) computed three ways and cross-checked —
//!
//!   * CPU forward algorithm (the paper's sequential routine),
//!   * AOT Pallas kernel, **full** schedule (one PJRT call, n ≤ FULL_N),
//!   * AOT Pallas kernel, **tiled** schedule (non-empty tile triples only),
//!
//! printing the sparsity win of tile-skipping.
//!
//!     make artifacts && cargo run --release --example ranking_offload

use parmce::graph::datasets::{Dataset, Scale};
use parmce::mce::ranking::{CpuTriangleBackend, TriangleBackend};
use parmce::runtime::engine::Engine;
use parmce::runtime::tri_rank::{tile_triples, PjrtTiledBackend, PjrtTriangleBackend};
use parmce::util::table::{fmt_count, fmt_secs, Table};

fn main() {
    let engine = match Engine::load_default() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("artifacts not built ({e}); run `make artifacts` first");
            std::process::exit(1);
        }
    };
    let tile_b = engine.constant("TILE_B").unwrap();
    println!(
        "engine: artifacts {:?}, FULL_N={}, TILE_B={tile_b}",
        engine.artifact_names(),
        engine.constant("FULL_N").unwrap()
    );

    let mut t = Table::new(
        "Triangle ranking backends (all must agree exactly)",
        &[
            "Dataset", "n", "m", "Σtri", "cpu(s)", "pjrt-full(s)", "pjrt-tiled(s)",
            "tile triples (nonempty/total)",
        ],
    );
    for d in [
        Dataset::DblpLike,
        Dataset::AsSkitterLike,
        Dataset::WikiTalkLike,
    ] {
        let g = d.graph(Scale::Tiny);

        let t0 = std::time::Instant::now();
        let cpu = CpuTriangleBackend.per_vertex(&g).unwrap();
        let cpu_s = t0.elapsed().as_secs_f64();

        let full_backend = PjrtTriangleBackend::new(&engine);
        let t1 = std::time::Instant::now();
        let full = full_backend.per_vertex(&g).unwrap();
        let full_s = t1.elapsed().as_secs_f64();

        let tiled_backend = PjrtTiledBackend(PjrtTriangleBackend::new(&engine));
        let t2 = std::time::Instant::now();
        let tiled = tiled_backend.per_vertex(&g).unwrap();
        let tiled_s = t2.elapsed().as_secs_f64();

        assert_eq!(cpu, full, "{}: full schedule disagrees", d.name());
        assert_eq!(cpu, tiled, "{}: tiled schedule disagrees", d.name());
        let (nonempty, total) = tile_triples(&g, tile_b);
        t.row(vec![
            d.name().into(),
            g.n().to_string(),
            g.m().to_string(),
            fmt_count(cpu.iter().sum::<u64>() / 3),
            fmt_secs(cpu_s),
            fmt_secs(full_s),
            fmt_secs(tiled_s),
            format!("{nonempty}/{total}"),
        ]);
        println!("✓ {}: three backends agree", d.name());
    }
    println!("\n{}", t.render());
}
