"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

This is the core correctness signal for the kernel layer: hypothesis sweeps
shapes/densities/dtypes and asserts allclose against the reference.  The
same oracle is cross-checked against the Rust CPU triangle counter through
the AOT artifact in rust/tests/artifact_roundtrip.rs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.tri_count import (
    common_neighbor_counts,
    tri_count_full,
    tri_count_tile,
)

jax.config.update("jax_platform_name", "cpu")


def adjacency(seed: int, n: int, p: float) -> jax.Array:
    return ref.random_adjacency(jax.random.PRNGKey(seed), n, p)


# ---------------------------------------------------------------------------
# tri_count_full: blocked masked matmul with VMEM accumulator
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,block", [(8, 4), (16, 8), (32, 8), (64, 16), (128, 32)])
@pytest.mark.parametrize("p", [0.0, 0.1, 0.5, 1.0])
def test_tri_full_matches_ref_grid(n: int, block: int, p: float) -> None:
    adj = adjacency(n * 1000 + int(p * 10), n, p)
    got = tri_count_full(adj, block=block)
    want = ref.tri_count_full_ref(adj)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    nb=st.integers(1, 6),
    block=st.sampled_from([4, 8, 16]),
    p=st.floats(0.0, 1.0),
)
def test_tri_full_matches_ref_hypothesis(seed: int, nb: int, block: int, p: float) -> None:
    n = nb * block
    adj = adjacency(seed, n, p)
    got = tri_count_full(adj, block=block)
    want = ref.tri_count_full_ref(adj)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=1e-4)


def test_tri_full_triangle_graph() -> None:
    # K3 plus an isolated vertex: each K3 vertex is in exactly one triangle.
    adj = jnp.zeros((4, 4), jnp.float32)
    for u, v in [(0, 1), (1, 2), (0, 2)]:
        adj = adj.at[u, v].set(1.0).at[v, u].set(1.0)
    got = np.asarray(tri_count_full(adj, block=2))
    np.testing.assert_allclose(got, [1.0, 1.0, 1.0, 0.0])


def test_tri_full_complete_graph() -> None:
    # K_n: every vertex is in C(n-1, 2) triangles.
    n = 16
    adj = jnp.ones((n, n), jnp.float32) - jnp.eye(n, dtype=jnp.float32)
    got = np.asarray(tri_count_full(adj, block=8))
    expect = (n - 1) * (n - 2) / 2
    np.testing.assert_allclose(got, np.full(n, expect))


def test_tri_full_rejects_non_multiple_block() -> None:
    adj = jnp.zeros((10, 10), jnp.float32)
    with pytest.raises(AssertionError):
        tri_count_full(adj, block=4)


# ---------------------------------------------------------------------------
# tri_count_tile: single tile triple (driven by the Rust scheduler)
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    b=st.sampled_from([4, 8, 16, 32]),
    p=st.floats(0.0, 1.0),
)
def test_tri_tile_matches_ref(seed: int, b: int, p: float) -> None:
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    a_ik = jax.random.bernoulli(k1, p, (b, b)).astype(jnp.float32)
    a_kj = jax.random.bernoulli(k2, p, (b, b)).astype(jnp.float32)
    a_ij = jax.random.bernoulli(k3, p, (b, b)).astype(jnp.float32)
    got = tri_count_tile(a_ik, a_kj, a_ij)
    want = ref.tri_count_tile_ref(a_ik, a_kj, a_ij)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_tile_decomposition_equals_full() -> None:
    """Accumulating tile triples over all (i,j,k) must equal the full kernel.

    This is exactly the contract rust/src/runtime/tri_rank.rs relies on.
    """
    n, b = 32, 8
    nb = n // b
    adj = adjacency(7, n, 0.4)
    acc = np.zeros(n, np.float32)
    a = np.asarray(adj)
    for i in range(nb):
        for j in range(nb):
            for k in range(nb):
                t = tri_count_tile(
                    jnp.asarray(a[i * b:(i + 1) * b, k * b:(k + 1) * b]),
                    jnp.asarray(a[k * b:(k + 1) * b, j * b:(j + 1) * b]),
                    jnp.asarray(a[i * b:(i + 1) * b, j * b:(j + 1) * b]),
                )
                acc[i * b:(i + 1) * b] += np.asarray(t)
    want = np.asarray(ref.tri_count_full_ref(adj))
    np.testing.assert_allclose(acc * 0.5, want, atol=1e-3)


def test_tile_skipping_empty_triples_is_lossless() -> None:
    """Zero tiles contribute zero — sparsity-aware skipping is exact."""
    b = 8
    zero = jnp.zeros((b, b), jnp.float32)
    a = jax.random.bernoulli(jax.random.PRNGKey(3), 0.5, (b, b)).astype(jnp.float32)
    for combo in [(zero, a, a), (a, zero, a), (a, a, zero)]:
        np.testing.assert_allclose(np.asarray(tri_count_tile(*combo)), np.zeros(b))


# ---------------------------------------------------------------------------
# common_neighbor_counts: ParPivot score vector
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([8, 16, 64]), p=st.floats(0.0, 1.0))
def test_pivot_scores_match_ref(seed: int, n: int, p: float) -> None:
    adj = adjacency(seed, n, p)
    cand = jax.random.bernoulli(jax.random.PRNGKey(seed ^ 0xFF), 0.5, (1, n)).astype(
        jnp.float32
    )
    got = common_neighbor_counts(cand, adj)
    want = ref.common_neighbor_counts_ref(cand, adj)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_pivot_scores_semantics() -> None:
    """Hand-checked: score(w) = |cand ∩ Γ(w)| on a path graph 0-1-2-3."""
    n = 4
    adj = jnp.zeros((n, n), jnp.float32)
    for u, v in [(0, 1), (1, 2), (2, 3)]:
        adj = adj.at[u, v].set(1.0).at[v, u].set(1.0)
    cand = jnp.zeros((1, n), jnp.float32).at[0, 1].set(1.0).at[0, 2].set(1.0)
    got = np.asarray(common_neighbor_counts(cand, adj))
    # Γ(0)={1}→1, Γ(1)={0,2}→1, Γ(2)={1,3}→1, Γ(3)={2}→1
    np.testing.assert_allclose(got, [1.0, 1.0, 1.0, 1.0])
