"""L2 model + AOT path tests: export specs, shapes, HLO text invariants."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.aot import to_hlo_text
from compile.kernels import ref


def test_export_specs_shapes() -> None:
    specs = model.export_specs()
    assert set(specs) == {"rank_tri_full", "rank_tri_tile", "pivot_scores"}
    for name, (fn, args) in specs.items():
        out = fn(*(jnp.zeros(a.shape, a.dtype) for a in args))
        assert isinstance(out, tuple) and len(out) == 1, name


def test_rank_tri_full_matches_ref_at_export_shape() -> None:
    n = model.FULL_N
    adj = ref.random_adjacency(jax.random.PRNGKey(0), n, 0.02)
    (got,) = model.rank_tri_full(adj)
    want = ref.tri_count_full_ref(adj)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)


def test_rank_tri_full_zero_padding_invariant() -> None:
    """Embedding a small graph in the padded FULL_N matrix changes nothing.

    This is the contract the Rust caller relies on when zero-padding.
    """
    n = model.FULL_N
    small = 40
    adj_small = ref.random_adjacency(jax.random.PRNGKey(5), small, 0.3)
    padded = jnp.zeros((n, n), jnp.float32).at[:small, :small].set(adj_small)
    (got,) = model.rank_tri_full(padded)
    want = ref.tri_count_full_ref(adj_small)
    np.testing.assert_allclose(np.asarray(got)[:small], np.asarray(want), atol=1e-3)
    np.testing.assert_allclose(np.asarray(got)[small:], 0.0)


def test_hlo_text_lowering_smoke() -> None:
    """Every exported fn lowers to parseable-looking HLO text with ENTRY."""
    for name, (fn, args) in model.export_specs().items():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        assert "ENTRY" in text, name
        assert "HloModule" in text, name
        # tuple return contract with the rust loader (to_tuple1)
        assert "tuple" in text.lower(), name


def test_hlo_is_deterministic() -> None:
    (fn, args) = model.export_specs()["rank_tri_tile"]
    t1 = to_hlo_text(jax.jit(fn).lower(*args))
    t2 = to_hlo_text(jax.jit(fn).lower(*args))
    assert t1 == t2
