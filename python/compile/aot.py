"""AOT compile path: lower the L2 model functions to HLO *text* artifacts.

HLO text (NOT ``lowered.compile()`` / ``.serialize()``) is the interchange
format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids which the
xla crate's bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md.

Run once at build time (``make artifacts``); the Rust binary is self-contained
afterwards — Python never runs on the enumeration path.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="legacy single-file output (model.hlo.txt)")
    args = ap.parse_args()

    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    manifest = {}
    for name, (fn, example_args) in model.export_specs().items():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "arg_shapes": [list(a.shape) for a in example_args],
            "chars": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")

    manifest["constants"] = {
        "FULL_N": model.FULL_N,
        "TILE_B": model.TILE_B,
        "PIVOT_N": model.PIVOT_N,
    }
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")

    if args.out:  # legacy Makefile target compatibility
        import shutil

        shutil.copy(os.path.join(out_dir, "rank_tri_tile.hlo.txt"), args.out)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
