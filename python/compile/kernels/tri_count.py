"""L1: Pallas triangle-count kernels.

Per-vertex triangle counts from a dense 0/1 adjacency matrix:

    tri(v) = 1/2 * sum_j ((A @ A) * A)[v, j]

This is the compute hot-spot of the ParMCETri vertex ranking (paper §4.2,
Table 5 "Ranking Time").  The paper computes it sequentially on a Xeon; here
it is re-thought for TPU-shaped hardware (DESIGN.md §Hardware-Adaptation):

  * the product is tiled into (B, B) VMEM blocks via BlockSpec (the TPU
    analogue of the CUDA threadblock/shared-memory staging the GPU
    literature uses for masked matmul),
  * the inner `a_ik @ a_kj` contraction targets the MXU systolic array,
  * the mask + row-reduction epilogue runs on the VPU,
  * a VMEM scratch accumulator carries the partial product across the `k`
    grid dimension (double-buffer-friendly revolving schedule).

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode lowers to plain HLO that the
Rust runtime (xla crate, PJRT CPU) runs bit-identically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default tile edge. 256 keeps the VMEM working set at
# (3 inputs + 1 scratch) * B^2 * 4B + B * 4B ≈ 1.05 MB — far under the
# ~16 MB VMEM of a TPU core, leaving headroom for double buffering.
DEFAULT_BLOCK = 256


def _tri_tile_kernel(a_ik_ref, a_kj_ref, a_ij_ref, out_ref, acc_ref, *, nk: int):
    """Grid (nI, nJ, nK) kernel body for blocked masked matmul + row reduce.

    For a fixed (i, j) output tile, the k steps accumulate
    ``acc += A[i,k] @ A[k,j]`` in the VMEM scratch; the final k step masks
    with ``A[i,j]`` and folds the row sums into ``out[i]``.
    """
    # program_id must be read at kernel top level (not inside pl.when
    # closures): the interpret-mode lowering only binds the primitive there.
    k = pl.program_id(2)
    j = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU contraction: (B, B) @ (B, B) in f32 (0/1 entries are exact).
    acc_ref[...] += jnp.dot(
        a_ik_ref[...], a_kj_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        masked = acc_ref[...] * a_ij_ref[...]
        partial = jnp.sum(masked, axis=1)

        @pl.when(j == 0)
        def _first():
            out_ref[...] = partial

        @pl.when(j != 0)
        def _rest():
            out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("block",))
def tri_count_full(adj: jax.Array, *, block: int = DEFAULT_BLOCK) -> jax.Array:
    """Per-vertex triangle counts for a full dense adjacency matrix.

    ``adj`` is an (n, n) f32 0/1 symmetric matrix with zero diagonal;
    n must be a multiple of ``block`` (the Rust caller zero-pads).
    Returns an (n,) f32 vector of triangle counts per vertex.
    """
    n = adj.shape[0]
    assert adj.shape == (n, n), "adjacency must be square"
    assert n % block == 0, f"n={n} must be a multiple of block={block}"
    nb = n // block
    counts2 = pl.pallas_call(
        functools.partial(_tri_tile_kernel, nk=nb),
        grid=(nb, nb, nb),
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j, k: (i, k)),  # A[i, k]
            pl.BlockSpec((block, block), lambda i, j, k: (k, j)),  # A[k, j]
            pl.BlockSpec((block, block), lambda i, j, k: (i, j)),  # mask A[i, j]
        ],
        out_specs=pl.BlockSpec((block,), lambda i, j, k: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        # (B, B) f32 VMEM accumulator carried across the k grid dimension.
        scratch_shapes=[pltpu.VMEM((block, block), jnp.float32)],
        interpret=True,
    )(adj, adj, adj)
    return counts2 * 0.5


def _tri_tile_triple_kernel(a_ik_ref, a_kj_ref, a_ij_ref, out_ref):
    """Single-tile-triple kernel: partial counts for one (i, j, k) block.

    Used by the Rust tiled scheduler for graphs too large for a dense
    matrix: the L3 side enumerates only the *non-empty* tile triples and
    accumulates the returned (B,) partial row counts per row block.
    """
    prod = jnp.dot(a_ik_ref[...], a_kj_ref[...], preferred_element_type=jnp.float32)
    out_ref[...] = jnp.sum(prod * a_ij_ref[...], axis=1)


@jax.jit
def tri_count_tile(a_ik: jax.Array, a_kj: jax.Array, a_ij: jax.Array) -> jax.Array:
    """Partial per-row triangle counts (×2, unmasked by ½) for one tile triple."""
    b = a_ik.shape[0]
    assert a_ik.shape == a_kj.shape == a_ij.shape == (b, b)
    return pl.pallas_call(
        _tri_tile_triple_kernel,
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(a_ik, a_kj, a_ij)


def _common_neighbors_kernel(cand_ref, adj_ref, out_ref):
    """Pivot-scoring kernel: |cand ∩ Γ(w)| for every vertex w.

    ``cand`` is a 0/1 indicator row (1, n); ``adj`` the dense adjacency.
    out[w] = Σ_u cand[u] · A[w, u]  — one VPU-friendly matvec.
    """
    out_ref[...] = jnp.dot(adj_ref[...], cand_ref[...].reshape(-1))


@jax.jit
def common_neighbor_counts(cand: jax.Array, adj: jax.Array) -> jax.Array:
    """|cand ∩ Γ(w)| for all w — the ParPivot score vector (paper Alg. 2)."""
    n = adj.shape[0]
    assert cand.shape == (1, n)
    return pl.pallas_call(
        _common_neighbors_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(cand, adj)
