"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness ground truth: pytest asserts the Pallas kernels
match these to float tolerance across a shape/density sweep, and the Rust
CPU implementation (`graph/triangles.rs`) is separately cross-checked
against the AOT artifact in `rust/tests/`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tri_count_full_ref(adj: jax.Array) -> jax.Array:
    """tri(v) = ½ Σ_j ((A @ A) ⊙ A)[v, j] — per-vertex triangle counts."""
    prod = jnp.matmul(adj, adj, preferred_element_type=jnp.float32)
    return 0.5 * jnp.sum(prod * adj, axis=1)


def tri_count_tile_ref(a_ik: jax.Array, a_kj: jax.Array, a_ij: jax.Array) -> jax.Array:
    """Partial (unmasked-by-½) row counts for one (i, j, k) tile triple."""
    prod = jnp.matmul(a_ik, a_kj, preferred_element_type=jnp.float32)
    return jnp.sum(prod * a_ij, axis=1)


def common_neighbor_counts_ref(cand: jax.Array, adj: jax.Array) -> jax.Array:
    """|cand ∩ Γ(w)| for every vertex w (ParPivot score vector)."""
    return jnp.matmul(adj, cand.reshape(-1))


def random_adjacency(key: jax.Array, n: int, p: float) -> jax.Array:
    """Symmetric 0/1 adjacency with zero diagonal, edge probability p."""
    upper = jax.random.bernoulli(key, p, (n, n)).astype(jnp.float32)
    upper = jnp.triu(upper, k=1)
    return upper + upper.T
