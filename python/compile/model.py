"""L2: the JAX compute graph exported to the Rust coordinator.

The paper's dense-tensor hot spot is the triangle-count vertex ranking of
ParMCETri (§4.2; its cost is the "Ranking Time" column of Table 5).  This
module wraps the L1 Pallas kernels in the exact computations the Rust side
loads as AOT artifacts:

  * ``rank_tri_full``  — whole-graph per-vertex triangle counts for dense
    adjacencies (n ≤ FULL_N, zero-padded by the caller).  One PJRT call.
  * ``rank_tri_tile``  — partial counts for one (i, j, k) adjacency tile
    triple; the Rust scheduler (runtime/tri_rank.rs) iterates the non-empty
    tile triples of a large sparse graph and accumulates.
  * ``pivot_scores``   — |cand ∩ Γ(w)| for all w, the ParPivot score vector
    over a dense subproblem adjacency (used by the GPU/TPU-offload ablation).

Every function is shape-monomorphic (AOT requires static shapes); the
constants below are the contract with the Rust side and are mirrored in
``rust/src/runtime/tri_rank.rs``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import tri_count as k

# Contract with rust/src/runtime/tri_rank.rs — keep in sync.
FULL_N = 512   # rank_tri_full operates on (FULL_N, FULL_N) dense adjacency
TILE_B = 256   # rank_tri_tile operates on (TILE_B, TILE_B) tiles
PIVOT_N = 512  # pivot_scores dense subproblem size


def rank_tri_full(adj: jax.Array) -> tuple[jax.Array]:
    """Per-vertex triangle counts of a (FULL_N, FULL_N) 0/1 adjacency."""
    return (k.tri_count_full(adj, block=128),)


def rank_tri_tile(a_ik: jax.Array, a_kj: jax.Array, a_ij: jax.Array) -> tuple[jax.Array]:
    """Partial row counts (×2) for one (TILE_B, TILE_B) tile triple."""
    return (k.tri_count_tile(a_ik, a_kj, a_ij),)


def pivot_scores(cand: jax.Array, adj: jax.Array) -> tuple[jax.Array]:
    """ParPivot score vector |cand ∩ Γ(w)| over a dense subproblem."""
    return (k.common_neighbor_counts(cand, adj),)


def export_specs() -> dict[str, tuple]:
    """name -> (fn, example ShapeDtypeStructs); consumed by aot.py."""
    f32 = jnp.float32
    full = jax.ShapeDtypeStruct((FULL_N, FULL_N), f32)
    tile = jax.ShapeDtypeStruct((TILE_B, TILE_B), f32)
    cand = jax.ShapeDtypeStruct((1, PIVOT_N), f32)
    padj = jax.ShapeDtypeStruct((PIVOT_N, PIVOT_N), f32)
    return {
        "rank_tri_full": (rank_tri_full, (full,)),
        "rank_tri_tile": (rank_tri_tile, (tile, tile, tile)),
        "pivot_scores": (pivot_scores, (cand, padj)),
    }
